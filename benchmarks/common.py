"""Shared benchmark helpers.

Benchmarks report two kinds of numbers:
* REAL numerics / wall-clock of this container's kernels (microbench),
* SIMULATED latencies at paper scale from the calibrated cost model —
  the policies are real (the paper's Algorithm 1 vs baselines); only the
  hardware clock is modelled, since this container has no GPU/TPU.
"""
import time
from typing import Dict, List

from repro.configs import get_config
from repro.core import FiddlerEngine, HardwareSpec

ENVS = {
    "env1": HardwareSpec.paper_env1(),   # Quadro RTX 6000 + Xeon Gold (paper)
    "env2": HardwareSpec.paper_env2(),   # RTX 6000 Ada + Xeon Platinum (paper)
    "tpuhost": HardwareSpec(),           # TPU v5e + host (this repo's target)
}

POLICIES = ("fiddler", "offload", "static_split")

_rows: List[Dict] = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    """CSV row in the required ``name,us_per_call,derived`` format."""
    _rows.append({"name": name, "us_per_call": us_per_call,
                  "derived": derived})
    print(f"{name},{us_per_call:.3f},{derived}")


def engine_for(model_name: str, policy: str, env: str, seed: int = 0,
               dataset_seed: int = 0, **kw) -> FiddlerEngine:
    cfg = get_config(model_name)
    return FiddlerEngine(cfg, policy=policy, hw=ENVS[env], seed=seed, **kw)


def timeit(fn, iters: int = 3, warmup: int = 1) -> float:
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters
