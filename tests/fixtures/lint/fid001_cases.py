"""FID001 fixture: host syncs in/out of the hot path.

Parsed by fiddlint, never imported.  EXPECT-comment markers name the
lines the rule must flag; everything else must stay clean.  The hot root
for this module is ``Engine.step`` (overridden in the test config).
"""
import jax.numpy as jnp
import numpy as np


def compute(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.tanh(x)


def helper(v: jnp.ndarray):
    # reachable from Engine.step via the call graph, so this syncs the
    # hot path even though it is two frames down
    w = int(v[0])  # EXPECT: FID001
    return w


def cold_path(v: jnp.ndarray):
    # false-positive candidate: same construct, but no path from the hot
    # root reaches this function
    return v.item()


def host_math(n):
    # false-positive candidate: float() on a plain python number
    return float(n) * 2.0


class Engine:
    def step(self, x: jnp.ndarray):
        logits = compute(x)
        t = logits.item()  # EXPECT: FID001
        y = np.asarray(logits)  # EXPECT: FID001
        z = float(logits[0])  # EXPECT: FID001
        u = logits.tolist()  # EXPECT: FID001
        w = helper(logits)
        host = np.asarray([1, 2, 3])  # ok: host-side literal, no sync
        scale = host_math(3)  # ok: host arithmetic
        return t, y, z, u, w, host, scale
