"""Continuous batching through the Fiddler orchestrator.

A request stream with Poisson arrival times flows through the slot-based
``ContinuousEngine`` over a ``FiddlerBackend``: prompts are admitted in
chunks (so a long admission never stalls in-flight decodes), the planner
sees the mixed in-flight batch's expert counts each step, and TTFT/ITL
are recorded in simulated seconds on the paper's env1 hardware spec.

Scheduling goes through the pluggable ``SchedulerPolicy`` seam
(serving/policy.py): this demo uses ``PriorityPolicy``, so the
``interactive``-class request jumps the queue — preempting a running
batch-class decode if no slot is free — and the preempted request still
produces exactly its unpreempted greedy output (it is re-admitted via
chunked prefill of its prompt + already-emitted tokens).

The engine also runs with dynamic placement rebalancing enabled
(core/rebalance.py): an online EWMA profile tracks the live routing and
a bounded number of experts migrate between tiers when it drifts —
migration transfer time shows up in the ledger, numerics never change.

A final section demos the cross-request prefix cache: two prompts share
a system preamble, and the second admission splices the preamble's
resident blocks out of the paged pool instead of re-prefilling them —
its TTFT visibly drops.

  PYTHONPATH=src python examples/serve_continuous.py [--smoke]

``--smoke`` (CI's examples-smoke lane) shrinks the run to its smallest
configuration: fewer requests, shorter generations, seconds on CPU.
"""
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import FiddlerEngine, HardwareSpec
from repro.data.tokenizer import ByteTokenizer
from repro.models import Model
from repro.serving.backend import FiddlerBackend
from repro.serving.continuous import ContinuousEngine
from repro.serving.engine import Request


def main(smoke: bool = False):
    full = get_config("mixtral-8x7b")
    cfg = full.reduced()  # real numerics at reduced scale on CPU
    model = Model(cfg, param_dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    tok = ByteTokenizer(cfg.vocab_size)

    fe = FiddlerEngine(cfg, params, policy="fiddler", timing_cfg=full,
                       hw=HardwareSpec.paper_env1(), host_precision="fp32",
                       expert_budget=cfg.n_layers * cfg.moe.n_experts // 4,
                       rebalance_interval=8, rebalance_k=2)
    eng = ContinuousEngine(FiddlerBackend(fe, max_seq=96),
                           n_slots=2 if smoke else 3,
                           max_seq=96, prefill_chunk=8, policy="priority")

    rng = np.random.default_rng(0)
    texts = ["the paper's fast tier", "experts on the slow tier",
             "orchestrate cpu and gpu", "mixture of experts serving",
             "continuous batching wins", "a longer prompt that needs "
             "several admission chunks before its first token"]
    if smoke:
        texts = texts[:2] + texts[-1:]
    t = 0.0
    for i, text in enumerate(texts):
        t += rng.exponential(1 / 8.0)  # 8 req/s Poisson load
        # the last arrival is an interactive-class request: it overtakes
        # the queued batch work (and may steal a busy decode slot)
        slo = "interactive" if i == len(texts) - 1 else "batch"
        eng.submit(Request(rid=f"req{i}", prompt=tok.encode(text)[:64],
                           max_new_tokens=4 if smoke else 12, arrival=t,
                           slo_class=slo))

    done = eng.run()
    assert len(done) == len(texts), (len(done), len(texts))
    for r in sorted(done, key=lambda r: r.rid):
        print(f"{r.rid}[{r.slo_class}]: ttft={r.ttft * 1e3:7.2f}ms(sim) "
              f"itl={(r.itl or 0) * 1e3:6.2f}ms(sim) "
              f"tokens={len(r.output)} preempt={r.preemptions} "
              f"text={tok.decode(r.output)!r}")
    led = fe.ledger
    print(f"ledger: sim_time={led.sim_time:.4f}s hits={led.fast_hits} "
          f"streams={led.streams} slow={led.slow_runs} "
          f"tokens_out={led.tokens_out} migrations={led.migrations} "
          f"migration_time={led.migration_time * 1e3:.1f}ms")

    # -- cross-request prefix cache ------------------------------------
    # Two prompts share a 32-token system preamble.  The second request
    # is submitted only after the first retires, so its preamble is
    # already resident in the paged pool: admission splices the shared
    # blocks into the slot's block table (refcount bump, copy-on-write
    # on any later divergent write) and chunk-prefills just the tail —
    # its TTFT drops accordingly.
    fe2 = FiddlerEngine(cfg, params, policy="fiddler", timing_cfg=full,
                        hw=HardwareSpec.paper_env1(), host_precision="fp32",
                        expert_budget=cfg.n_layers * cfg.moe.n_experts // 4)
    eng2 = ContinuousEngine(FiddlerBackend(fe2, max_seq=96), n_slots=1,
                            max_seq=96, prefill_chunk=8)
    pre = rng.integers(3, cfg.vocab_size, size=32).tolist()
    done2 = []
    for i, tail in enumerate(("the cpu expert tier", "the gpu expert tier")):
        eng2.submit(Request(rid=f"pfx{i}",
                            prompt=pre + tok.encode(tail)[:16],
                            max_new_tokens=4))
        done2 = eng2.run()  # sequential: TTFT is pure admission latency
    cold, warm = sorted(done2, key=lambda r: r.rid)
    led2 = fe2.ledger
    stats = eng2.backend.block_stats(eng2.cache)
    print(f"prefix cache: cold ttft={cold.ttft * 1e3:7.2f}ms(sim) "
          f"warm ttft={warm.ttft * 1e3:7.2f}ms(sim) "
          f"hits={led2.prefix_hits}/{led2.prefix_lookups} "
          f"matched_tokens={led2.prefix_tokens} "
          f"cached_blocks={stats['cached_blocks']}")
    assert led2.prefix_hits >= 1, "warm admission should hit the prefix cache"
    assert warm.ttft < cold.ttft, (warm.ttft, cold.ttft)


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv)
