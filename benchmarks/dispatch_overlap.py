"""Grouped-GEMM dispatch + idle-link migration-prefetch benchmark.

Two sections, two kinds of numbers:

1. **Real numerics / wall-clock** (reduced Phi-3.5-MoE, 16 experts, all
   resident): a multi-slot continuous-decode loop — the paper's hot
   regime, nearly every routed expert sees 1–2 rows — run through the
   per-expert eager loop (the pre-PR-4 execution path) and the grouped
   dispatcher (default: one capacity-bucketed launch per tier group).
   Reported: fast-tier kernel dispatches per layer-step, wall-clock
   seconds per decode step on this container, and whether grouped output
   logits are bit-identical to eager on fp32 (they must be).

2. **Simulated migration overlap** (full-size Mixtral-8x7B, paper env1):
   a routing shift forces the Rebalancer to migrate experts while decode
   traffic flows.  Sync mode charges every promotion ``transfer_lat()``
   serially; async prefetch rides idle link windows and only exposes the
   remainder.  Reported: overlapped vs exposed migration seconds and the
   end-to-end simulated-time saving.

Results land in ``BENCH_dispatch_overlap.json`` (committed copy must be
full mode; CI's bench-smoke lane runs ``--smoke`` and validates keys).
"""
from __future__ import annotations

import json
import time
from pathlib import Path
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import ENVS, emit
from repro.configs import get_config
from repro.core import FiddlerEngine
from repro.core.popularity import ExpertProfile, synthetic_profile
from repro.models import Model

RESULTS_JSON = Path(__file__).resolve().parents[1] / "BENCH_dispatch_overlap.json"
MAX_SEQ = 32

DISPATCH_VARIANTS = {
    "eager": dict(dispatch_mode="eager"),
    "grouped": dict(dispatch_mode="grouped"),
}


# ---------------------------------------------------------------------------
# Section 1: real-numerics dispatch count + wall clock
# ---------------------------------------------------------------------------


def _decode_trace(eng, n_slots: int, n_steps: int):
    """Prefill ``n_slots`` tiny prompts into slots, then run ``n_steps``
    multi-slot decode steps.  Returns (stacked logits, dispatches during
    decode, wall seconds of the decode loop)."""
    caches = eng.make_decode_caches(n_slots, MAX_SEQ)
    for slot in range(n_slots):
        prompt = jnp.asarray([[1 + slot, 5, 9 + slot]], jnp.int32)
        _, sc = eng.prefill_chunk(prompt, None, 0, MAX_SEQ)
        caches = eng.write_slot(caches, sc, slot)
    tokens = jnp.asarray(np.arange(3, 3 + n_slots)[:, None], jnp.int32)
    pos = np.full(n_slots, 3)
    outs = []
    d0 = eng.ledger.fast_dispatches
    t0 = time.perf_counter()
    for step in range(n_steps):
        logits, caches = eng.decode_step_multi(caches, tokens, pos + step,
                                               MAX_SEQ)
        outs.append(np.asarray(logits))
    wall = time.perf_counter() - t0
    return np.stack(outs), eng.ledger.fast_dispatches - d0, wall


def real_dispatch_section(model: str, n_slots: int, n_steps: int,
                          d_model: int, max_experts: int,
                          repeats: int = 5) -> Dict[str, Dict]:
    cfg = get_config(model).reduced(n_layers=2, d_model=d_model,
                                    max_experts=max_experts)
    mdl = Model(cfg, param_dtype=jnp.float32)
    params = mdl.init(jax.random.PRNGKey(42))
    L = cfg.n_layers
    results: Dict[str, Dict] = {}
    logits = {}
    for name, kw in DISPATCH_VARIANTS.items():
        eng = FiddlerEngine(cfg, params, policy="fiddler",
                            expert_budget=L * cfg.moe.n_experts,
                            host_precision="fp32", **kw)
        # pass 1 compiles every shape this trace routes through (grouped
        # bucket/uniform signatures and eager per-count ones alike); the
        # timed passes replay the identical trace, and the median over
        # ``repeats`` damps container timing noise (the dispatch counts
        # are deterministic; only wall-clock needs the repeats)
        _decode_trace(eng, n_slots, n_steps)
        walls = []
        for _ in range(repeats):
            out, dispatches, wall = _decode_trace(eng, n_slots, n_steps)
            walls.append(wall)
        logits[name] = out
        key = f"dispatch/{cfg.name}/{name}"
        r = {
            "dispatches_per_layer_step": dispatches / (n_steps * L),
            "wall_s_per_step": float(np.median(walls)) / n_steps,
            "wall_s_per_step_spread": [min(walls) / n_steps,
                                       max(walls) / n_steps],
            "timed_repeats": repeats,
            "decode_steps": n_steps,
            "n_slots": n_slots,
        }
        emit(key, r["wall_s_per_step"] * 1e6,
             f"disp_per_layer={r['dispatches_per_layer_step']:.2f}")
        results[key] = r
    results[f"dispatch/{cfg.name}/grouped"]["bit_identical_fp32"] = \
        bool(np.array_equal(logits["grouped"], logits["eager"]))
    return results


# ---------------------------------------------------------------------------
# Section 2: simulated idle-link migration prefetch
# ---------------------------------------------------------------------------


def overlap_section(model: str, env: str, n_steps: int, batch: int,
                    interval: int, k: int) -> Dict[str, Dict]:
    cfg = get_config(model)
    L, E = cfg.n_layers, cfg.moe.n_experts
    calib = synthetic_profile(L, E, seed=0, concentration=0.5)
    rng = np.random.default_rng(1)
    shifted = ExpertProfile(np.stack(
        [calib.counts[li][rng.permutation(E)] for li in range(L)]))
    results: Dict[str, Dict] = {}
    for name, async_on in (("async", True), ("sync", False)):
        eng = FiddlerEngine(cfg, policy="fiddler", hw=ENVS[env],
                            profile=calib, expert_budget=L * E // 4,
                            seed=0, rebalance_interval=interval,
                            rebalance_k=k, async_prefetch=async_on)
        eng.profile = shifted   # routing shift → migrations fire
        for _ in range(n_steps):
            eng.simulate_decode(1, batch=batch)
            eng.maybe_rebalance()
        eng.flush_prefetch()
        led = eng.ledger
        key = f"overlap/{env}/{name}"
        r = {
            "migrations": led.migrations,
            "migration_time": led.migration_time,
            "migration_overlapped": led.migration_overlapped,
            "migration_exposed": led.migration_exposed,
            "migration_bytes": led.migration_bytes,
            "sim_time": led.sim_time,
            "serial_charge": led.migrations * eng.lat.transfer_lat(),
        }
        emit(key, r["migration_exposed"] * 1e6,
             f"overlapped={r['migration_overlapped'] * 1e3:.1f}ms "
             f"exposed={r['migration_exposed'] * 1e3:.1f}ms "
             f"of {r['migration_time'] * 1e3:.1f}ms")
        results[key] = r
    return results


# ---------------------------------------------------------------------------
# Harness
# ---------------------------------------------------------------------------


def run(fast: bool = False, smoke: bool = False) -> Dict[str, Dict]:
    if smoke:
        disp = dict(model="phi-3.5-moe", n_slots=2, n_steps=2,
                    d_model=128, max_experts=8, repeats=1)
        over = dict(model="mixtral-8x7b", env="env1", n_steps=8,
                    batch=4, interval=4, k=8)
    elif fast:
        disp = dict(model="phi-3.5-moe", n_slots=4, n_steps=4,
                    d_model=128, max_experts=16)
        over = dict(model="mixtral-8x7b", env="env1", n_steps=32,
                    batch=4, interval=4, k=8)
    else:
        disp = dict(model="phi-3.5-moe", n_slots=8, n_steps=8,
                    d_model=128, max_experts=16)
        over = dict(model="mixtral-8x7b", env="env1", n_steps=64,
                    batch=4, interval=4, k=8)
    results = {}
    results.update(real_dispatch_section(**disp))
    results.update(overlap_section(**over))

    cfg_name = get_config(disp["model"]).reduced(
        n_layers=2, d_model=disp["d_model"],
        max_experts=disp["max_experts"]).name
    grouped = results[f"dispatch/{cfg_name}/grouped"]
    eager = results[f"dispatch/{cfg_name}/eager"]
    a, s = results[f"overlap/{over['env']}/async"], \
        results[f"overlap/{over['env']}/sync"]
    record = {
        "_meta": {
            "mode": "smoke" if smoke else ("fast" if fast else "full"),
            "dispatch": disp, "overlap": over,
        },
        "results": results,
        "summary": {
            "dispatch_reduction_x":
                eager["dispatches_per_layer_step"]
                / max(grouped["dispatches_per_layer_step"], 1e-12),
            "wall_clock_speedup_x":
                eager["wall_s_per_step"]
                / max(grouped["wall_s_per_step"], 1e-12),
            "bit_identical_fp32": grouped["bit_identical_fp32"],
            "exposed_leq_serial":
                a["migration_exposed"] <= a["serial_charge"] + 1e-12,
            "migration_bytes_unchanged":
                a["migration_bytes"] == s["migration_bytes"],
            "exposed_over_serial_ratio":
                a["migration_exposed"] / max(a["serial_charge"], 1e-12),
            "async_sim_time_saving_s": s["sim_time"] - a["sim_time"],
        },
    }
    RESULTS_JSON.write_text(json.dumps(record, indent=2, sort_keys=True))
    return results


if __name__ == "__main__":
    import sys

    run(fast="--full" not in sys.argv, smoke="--smoke" in sys.argv)
