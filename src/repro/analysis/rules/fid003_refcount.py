"""FID003 block-refcount-escape.

Paged-KV blocks are manually refcounted: ``alloc``/``fork_slot``/
``map_prefix`` take a reference, ``release_slot``/``free``/``_unref``
drop it.  A path that acquires and then exits without releasing strands
blocks until pool exhaustion — the PR-5 class of bug this rule encodes.

Scope and ownership model (documented under-approximations):

* Acquires whose receiver is rooted at ``self`` are skipped: the object
  owns the reference and its own release paths (``__del__``-style
  bookkeeping is the class's concern, checked by ``BlockMeta.check()``
  at runtime).
* A **bound** acquire (``blocks = pool.alloc(n)``) must, on *every* path
  out of the function — returns, falls off the end, raises, or an
  except-handler swallows — either release or hand the value off
  (return it, pass it to a call, store it into an attribute/container).
* A **statement-form** acquire (``cache.meta.map_prefix(slot, chain)``)
  records ownership inside the receiver, so normal exits are fine; only
  abnormal exits are flagged: a ``raise`` while holding, or an except
  handler that swallows the error without releasing.
* A ``finally`` block that releases covers every path through its
  ``try`` — the canonical safe pattern.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass
from typing import List, Optional, Set, Tuple

from repro.analysis.config import FiddlintConfig
from repro.analysis.core import Finding, relpath
from repro.analysis.project import FunctionInfo, Project, attr_chain

# scan() path states
BEFORE, HELD, DONE = "before", "held", "done"

# builtins that inspect a value without taking ownership of it
NON_OWNING_CALLS = {
    "len", "int", "float", "bool", "str", "repr", "print", "sorted",
    "list", "tuple", "set", "sum", "min", "max", "enumerate", "range",
    "isinstance", "id", "type", "iter", "next", "zip", "any", "all",
}


@dataclass
class Acquire:
    call: ast.Call
    method: str
    var: Optional[str]     # bound name, or None for statement-form
    receiver: Optional[str]
    bound: bool


def _names_in(node: ast.AST) -> Set[str]:
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


def _find_acquires(fn: FunctionInfo, config: FiddlintConfig
                   ) -> List[Acquire]:
    out: List[Acquire] = []
    assigned: Set[int] = set()
    for node in ast.walk(fn.node):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            call = node.value
            acq = _classify(call, config)
            if acq and len(node.targets) == 1 and isinstance(
                    node.targets[0], ast.Name):
                acq.var = node.targets[0].id
                acq.bound = True
                out.append(acq)
                assigned.add(id(call))
    for node in ast.walk(fn.node):
        if isinstance(node, ast.Call) and id(node) not in assigned:
            acq = _classify(node, config)
            if acq:
                out.append(acq)
    return out


def _classify(call: ast.Call, config: FiddlintConfig) -> Optional[Acquire]:
    chain = attr_chain(call.func)
    if not chain or chain[-1] not in config.acquire_methods:
        return None
    if len(chain) < 2:
        return None  # bare name, not a method on an owner object
    if chain[0] == "self":
        return None  # object-owned; the class's own invariant
    return Acquire(call=call, method=chain[-1], var=None,
                   receiver=chain[0], bound=False)


class _PathScan:
    """Statement-level walk tracking one acquire's ownership state."""

    def __init__(self, acq: Acquire, config: FiddlintConfig):
        self.acq = acq
        self.config = config
        self.leaks: List[Tuple[int, str]] = []  # (line, kind)

    # -- event classification ----------------------------------------------
    def _mentions(self, node: ast.AST) -> bool:
        if self.acq.var is not None:
            return self.acq.var in _names_in(node)
        return (self.acq.receiver is not None
                and self.acq.receiver in _names_in(node))

    def _is_release(self, node: ast.AST) -> bool:
        for call in ast.walk(node):
            if not isinstance(call, ast.Call):
                continue
            chain = attr_chain(call.func)
            if not chain or chain[-1] not in self.config.release_methods:
                continue
            involved = _names_in(call.func) | set()
            for a in [*call.args, *[k.value for k in call.keywords]]:
                involved |= _names_in(a)
            if self.acq.var is not None:
                if self.acq.var in involved:
                    return True
            elif self.acq.receiver in involved:
                return True
        return False

    def _is_handoff(self, node: ast.AST) -> bool:
        """Bound value escapes: passed to a call, stored, yielded."""
        if self.acq.var is None:
            return False
        var = self.acq.var
        for inner in ast.walk(node):
            if isinstance(inner, ast.Call) and inner is not self.acq.call:
                if (isinstance(inner.func, ast.Name)
                        and inner.func.id in NON_OWNING_CALLS):
                    continue
                for a in [*inner.args, *[k.value for k in inner.keywords]]:
                    if var in _names_in(a):
                        return True
            if isinstance(inner, (ast.Yield, ast.YieldFrom)):
                if inner.value is not None and var in _names_in(inner.value):
                    return True
            if isinstance(inner, ast.Assign):
                for t in inner.targets:
                    if (isinstance(t, (ast.Attribute, ast.Subscript))
                            and var in _names_in(inner.value)):
                        return True
        return False

    def _step_state(self, stmt: ast.AST, state: str) -> str:
        """State transition for one simple statement."""
        if state == BEFORE:
            for call in ast.walk(stmt):
                if call is self.acq.call:
                    return HELD
            return BEFORE
        if state == HELD:
            if self._is_release(stmt) or self._is_handoff(stmt):
                return DONE
        return state

    # -- traversal -----------------------------------------------------------
    def scan(self, stmts: List[ast.stmt], states: Set[str]) -> Set[str]:
        """Propagate the set of possible states through a statement list;
        returns the fallthrough states (terminated paths emit leaks and
        drop out)."""
        cur = set(states)
        for stmt in stmts:
            if not cur:
                break
            cur = self._scan_stmt(stmt, cur)
        return cur

    def _scan_stmt(self, stmt: ast.stmt, states: Set[str]) -> Set[str]:
        if isinstance(stmt, ast.Return):
            nxt = {self._step_state(stmt, s) for s in states}
            if HELD in nxt:
                held_is_handoff = (stmt.value is not None
                                   and self._mentions(stmt.value)
                                   and self.acq.bound)
                if not held_is_handoff:
                    self.leaks.append((stmt.lineno, "return"))
            return set()
        if isinstance(stmt, ast.Raise):
            if HELD in {self._step_state(stmt, s) for s in states}:
                self.leaks.append((stmt.lineno, "raise"))
            return set()
        if isinstance(stmt, ast.If):
            a = self.scan(stmt.body, states)
            b = self.scan(stmt.orelse, states)
            return a | b
        if isinstance(stmt, (ast.For, ast.While, ast.AsyncFor)):
            body = self.scan(stmt.body, states)
            out = states | body  # loop may run zero times
            return self.scan(stmt.orelse, out) if stmt.orelse else out
        if isinstance(stmt, (ast.With, ast.AsyncWith)):
            entry = {self._step_state(stmt, s) for s in states}
            return self.scan(stmt.body, entry)
        if isinstance(stmt, ast.Try):
            return self._scan_try(stmt, states)
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            return states  # nested defs execute later; out of scope
        return {self._step_state(stmt, s) for s in states}

    def _scan_try(self, stmt: ast.Try, states: Set[str]) -> Set[str]:
        # a releasing finally covers every path through this try
        final_releases = any(self._is_release(s) or self._is_handoff(s)
                             for s in stmt.finalbody)
        body_out = self.scan(stmt.body, states)
        body_held = HELD in body_out or self._acquired_in(stmt.body, states)
        if final_releases:
            # drop leaks recorded inside the body: finally runs on them too
            self.leaks = [lk for lk in self.leaks
                          if not self._line_within(lk[0], stmt)]
            return {DONE if s == HELD else s for s in body_out} or {DONE}
        handler_entry = {HELD} if body_held else (states | body_out) or states
        out = set(body_out)
        for handler in stmt.handlers:
            h_out = self.scan(handler.body, set(handler_entry))
            if HELD in h_out and not self._reraises(handler):
                self.leaks.append((handler.lineno, "swallow"))
                # the leak is reported once, here; don't re-report it at
                # every later exit the handler path flows into
                h_out = {DONE if s == HELD else s for s in h_out}
            out |= h_out
        out = self.scan(stmt.orelse, out) if stmt.orelse else out
        return self.scan(stmt.finalbody, out) if stmt.finalbody else out

    def _acquired_in(self, stmts: List[ast.stmt], states: Set[str]) -> bool:
        if BEFORE not in states:
            return False
        for s in stmts:
            for call in ast.walk(s):
                if call is self.acq.call:
                    return True
        return False

    @staticmethod
    def _line_within(line: int, node: ast.AST) -> bool:
        end = getattr(node, "end_lineno", None) or node.lineno
        return node.lineno <= line <= end

    @staticmethod
    def _reraises(handler: ast.ExceptHandler) -> bool:
        return any(isinstance(n, ast.Raise) for n in ast.walk(handler))


def check_refcount(project: Project,
                   config: FiddlintConfig) -> List[Finding]:
    out: List[Finding] = []
    for fn in project.functions.values():
        path = relpath(fn.file.path)
        body = getattr(fn.node, "body", [])
        for acq in _find_acquires(fn, config):
            scan = _PathScan(acq, config)
            end_states = scan.scan(body, {BEFORE})
            leaks = list(scan.leaks)
            if HELD in end_states:
                leaks.append((acq.call.lineno, "end"))
            if not acq.bound:
                leaks = [lk for lk in leaks if lk[1] in ("raise", "swallow")]
            for line, kind in leaks:
                what = (f"`{acq.var}` from `{acq.method}`" if acq.bound
                        else f"reference taken by `{acq.method}` on "
                             f"`{acq.receiver}`")
                how = {
                    "return": "returns without releasing or handing off",
                    "raise": "raises while the reference is held",
                    "swallow": "except handler swallows the error without "
                               "releasing",
                    "end": "falls off the end without releasing or handing "
                           "off",
                }[kind]
                out.append(Finding(
                    "FID003", path, line, 0,
                    f"block-refcount escape: {what} — path {how} "
                    f"(every exit must release or transfer ownership)",
                    fn.qualname))
    return out
