"""Fiddler's latency model (paper §3.3, Appendix A).

The paper models, per expert and per MoE layer:

* ``gpu_lat(s)``      — fast-tier execution: ~constant in the input size
  ``s`` because one expert's GEMMs are memory-bandwidth-bound until ``s``
  reaches MXU saturation (the paper observes the same on GPUs).
* ``cpu_lat(s)``      — slow-tier execution: ~linear in ``s`` (compute
  bound).
* ``transfer_lat()``  — streaming one expert's weights over the host link:
  constant (weight bytes / link bandwidth).
* activation transfer — negligible (<1% of a single-input latency, paper
  App. A), modelled as a small linear term for completeness.

Constants come from either (a) TPU-v5e-flavoured hardware specs (the
``derive`` constructor — the napkin-math defaults used by benchmarks), or
(b) runtime measurement of the actual kernels (``calibrate`` — mirrors the
paper's initialization-phase microbenchmarks).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable

import numpy as np

from repro.configs.base import ModelConfig


# ---------------------------------------------------------------------------
# Hardware description (TPU v5e + host, per DESIGN.md §2)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class HardwareSpec:
    name: str = "tpu-v5e-host"
    fast_flops: float = 197e12        # bf16 peak per chip
    fast_mem_bw: float = 819e9        # HBM GB/s
    slow_flops: float = 3.3e12        # host CPU bf16 GEMM (AVX512-class, all cores)
    slow_mem_bw: float = 150e9        # host DRAM
    link_bw: float = 32e9             # host↔device DMA per host (PCIe-class)
    ici_bw: float = 50e9              # inter-chip link (roofline collective term)
    fast_capacity: float = 16e9       # HBM bytes per chip
    slow_capacity: float = 256e9      # host DRAM bytes

    @property
    def alltoall_bw(self) -> float:
        """Per-link inter-fast-device bandwidth for expert-parallel
        all-to-all routing.  Uses the ICI/NVLink rate when the platform
        has one; the paper envs are single-GPU boxes (``ici_bw=0``), so
        a multi-GPU extrapolation of them falls back to PCIe
        peer-to-peer at the host-link rate."""
        return self.ici_bw if self.ici_bw > 0 else self.link_bw

    @staticmethod
    def paper_env1() -> "HardwareSpec":
        """Quadro RTX 6000 + Xeon Gold 6126 (paper Table 1), for replaying
        the paper's setting in benchmarks."""
        return HardwareSpec(
            name="paper-env1", fast_flops=16.3e12, fast_mem_bw=672e9,
            slow_flops=1.3e12, slow_mem_bw=100e9, link_bw=32e9, ici_bw=0.0,
            fast_capacity=24.576e9, slow_capacity=192e9)

    @staticmethod
    def paper_env2() -> "HardwareSpec":
        """RTX 6000 Ada + Xeon Platinum 8480+ (paper Table 1)."""
        return HardwareSpec(
            name="paper-env2", fast_flops=91.1e12, fast_mem_bw=960e9,
            slow_flops=3.8e12, slow_mem_bw=300e9, link_bw=64e9, ici_bw=0.0,
            fast_capacity=49.140e9, slow_capacity=512e9)


# ---------------------------------------------------------------------------
# Expert geometry
# ---------------------------------------------------------------------------


def expert_weight_bytes(cfg: ModelConfig, bytes_per_param: int = 2) -> int:
    """3 matrices of (d_model, d_ff) per expert (gate/up/down)."""
    return 3 * cfg.d_model * cfg.d_ff * bytes_per_param


def expert_flops_per_token(cfg: ModelConfig) -> float:
    return 2.0 * 3 * cfg.d_model * cfg.d_ff


def activation_bytes(cfg: ModelConfig, s: int, bytes_per_el: int = 2) -> int:
    return s * cfg.d_model * bytes_per_el


# ---------------------------------------------------------------------------
# Latency model
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LatencyModel:
    """cpu_lat(s) = cpu_base + cpu_per_token·s         (linear — paper)
    gpu_lat(s) = gpu_const (+ tiny gpu_per_token·s)    (~constant — paper)
    transfer_lat() = weight bytes / link bw            (constant)
    act_transfer(s) = activation bytes / link bw       (negligible)"""

    gpu_const: float
    gpu_per_token: float
    cpu_base: float
    cpu_per_token: float
    weight_transfer: float
    act_per_token: float

    def gpu_lat(self, s) -> np.ndarray:
        s = np.asarray(s, np.float64)
        return np.where(s > 0, self.gpu_const + self.gpu_per_token * s, 0.0)

    def cpu_lat(self, s) -> np.ndarray:
        s = np.asarray(s, np.float64)
        return np.where(s > 0, self.cpu_base + self.cpu_per_token * s
                        + self.act_per_token * s, 0.0)

    def transfer_lat(self) -> float:
        return self.weight_transfer

    # -- constructors --------------------------------------------------------
    @staticmethod
    def derive(cfg: ModelConfig, hw: HardwareSpec = HardwareSpec()
               ) -> "LatencyModel":
        """Napkin-math latencies from hardware specs (paper App. A shape)."""
        wb = expert_weight_bytes(cfg)
        fl = expert_flops_per_token(cfg)
        return LatencyModel(
            # one expert on the fast tier: HBM-bound weight read
            gpu_const=wb / hw.fast_mem_bw,
            # MXU time per extra token (tiny until s saturates the MXU)
            gpu_per_token=fl / hw.fast_flops,
            # slow tier: DRAM-bound weight read floor (the expert's 3
            # matrices stream from host memory once per call — this is why
            # per-beam unbatched execution is catastrophic, paper §2.2)
            # + compute-bound per-token term
            cpu_base=wb / hw.slow_mem_bw,
            cpu_per_token=fl / hw.slow_flops,
            weight_transfer=wb / hw.link_bw,
            act_per_token=2 * activation_bytes(cfg, 1) / hw.link_bw,
        )

    @staticmethod
    def calibrate(fast_fn: Callable[[int], float],
                  slow_fn: Callable[[int], float],
                  transfer_fn: Callable[[], float],
                  sizes=(1, 2, 4, 8, 16, 32)) -> "LatencyModel":
        """Fit the model from measured (wall-clock) kernel runs — the
        paper's initialization-phase measurement.  ``fast_fn(s)``/
        ``slow_fn(s)`` return seconds for one expert on input size s."""
        sizes = np.asarray(sizes, np.float64)
        fast = np.asarray([fast_fn(int(s)) for s in sizes])
        slow = np.asarray([slow_fn(int(s)) for s in sizes])
        # linear fits
        fa = np.polyfit(sizes, fast, 1)
        sa = np.polyfit(sizes, slow, 1)
        return LatencyModel(
            gpu_const=max(float(fa[1]), 1e-9),
            gpu_per_token=max(float(fa[0]), 0.0),
            cpu_base=max(float(sa[1]), 0.0),
            cpu_per_token=max(float(sa[0]), 1e-12),
            weight_transfer=max(float(transfer_fn()), 1e-9),
            act_per_token=0.0,
        )

    # -- the paper's decision rule -------------------------------------------
    def prefer_cpu(self, s) -> np.ndarray:
        """Algorithm 1 line 12 (inverted): True → execute on CPU."""
        return self.cpu_lat(s) <= self.gpu_lat(s) + self.transfer_lat()

    def crossover(self, max_s: int = 1 << 20) -> int:
        """Input size above which streaming weights beats CPU execution."""
        lo, hi = 1, max_s
        if self.prefer_cpu(hi):
            return max_s
        while lo < hi:
            mid = (lo + hi) // 2
            if self.prefer_cpu(mid):
                lo = mid + 1
            else:
                hi = mid
        return int(lo)


def kv_read_entries(kv_len, kv_unique=None) -> float:
    """KV entries one layer step reads from memory.  Dense layout: every
    slot streams its own cache (``sum(kv_len)``).  Paged layout with
    prefix sharing: ``kv_unique`` — the distinct written block entries —
    overrides it, so a beam group's shared prompt is charged once.  The
    attention *flop* term stays per-token (every beam's query still
    scores against its full context); only the bytes dedup."""
    if kv_unique is not None:
        return float(kv_unique)
    return float(np.sum(kv_len)) if np.ndim(kv_len) else float(kv_len)


def alltoall_time(cfg: ModelConfig, n_remote_assignments: float,
                  hw: HardwareSpec, n_devices: int,
                  bytes_per_el: int = 2) -> float:
    """Seconds one MoE layer spends exchanging dispatch activations
    between fast devices under expert parallelism: every token routed to
    an expert resident on another device crosses the fabric twice (the
    dispatch all-to-all and the combine all-to-all back), and the D
    per-device links move their shares concurrently."""
    if n_devices <= 1 or n_remote_assignments <= 0:
        return 0.0
    bytes_moved = 2.0 * n_remote_assignments * cfg.d_model * bytes_per_el
    return bytes_moved / (hw.alltoall_bw * n_devices)


def link_idle_time(t_nonexpert: float, t_moe: float,
                   t_stream: float) -> float:
    """Seconds of one charged layer during which the host↔device link is
    idle: the layer's wall-clock (non-expert + MoE) minus the time
    FAST_STREAM weight transfers keep the link busy.  Asynchronous
    migration prefetches (core/rebalance.py ``PrefetchQueue``) ride
    exactly this window — the paper's idle-resource observation applied
    to the link instead of the GPU."""
    return max(0.0, t_nonexpert + t_moe - t_stream)


def measure(fn: Callable[[], None], iters: int = 5, warmup: int = 2) -> float:
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters
