"""Gemma2-9B [arXiv:2408.00118] — local+global alternating, logit softcap.

42L d_model=3584 16H (GQA kv=8) d_ff=14336 vocab=256000.
Even layers: sliding window 4096; odd layers: global. Softcaps: attn 50,
final logits 30.
"""
from repro.configs.base import ModelConfig, register


@register("gemma2-9b")
def gemma2_9b() -> ModelConfig:
    return ModelConfig(
        name="gemma2-9b",
        arch_type="dense",
        n_layers=42,
        d_model=3584,
        n_heads=16,
        n_kv_heads=8,
        head_dim=256,
        d_ff=14336,
        vocab_size=256000,
        window=4096,
        attn_pattern="alternating",
        logit_softcap=30.0,
        attn_softcap=50.0,
        act="gelu",
        tie_embeddings=True,
        scale_embeddings=True,
        citation="[arXiv:2408.00118] Gemma 2",
    )
