"""Continuous batching: a fixed pool of decode slots, each at its own
position; requests join as slots free up and leave on EOS/max-tokens —
no head-of-line blocking like the static grouped engine.

Runs over any ``ServingBackend``:

* ``ModelBackend``     — jitted monolithic ``Model`` (scatter cache writes,
  see kv_cache.write_decode_multi); wall-clock metrics.
* ``FiddlerBackend``   — the paper's CPU-GPU orchestrator: the planner sees
  the mixed in-flight batch's expert counts each step and the ledger
  advances in simulated seconds, which is also the clock that TTFT/ITL
  are recorded from.
* ``SimulatedBackend`` — no weights: routing sampled from the popularity
  profile, only the ledger advances (paper-scale load sweeps).

Admission can be **chunked** (``prefill_chunk=N``): a long prompt is
prefilled N tokens per engine step into a batch-1 staging cache while the
in-flight slots keep decoding, then joins the multi-slot cache — so one
long admission never stalls the whole pool.  Requests may carry an
``arrival`` time (load generators set it in backend-clock units); the
engine admits a request only once the clock has reached it.

Scheduling decisions — admission order, preemption victims, and the live
slot-pool size — are delegated to a pluggable ``SchedulerPolicy`` (see
serving/policy.py).  The default ``FIFOPolicy`` reproduces the engine's
pre-policy behavior exactly.  Preempted requests return to the queue
carrying their generated tokens and are re-admitted through the (chunked)
prefill path: the prompt plus all-but-the-last emitted token is
re-prefilled, then decoding resumes from the last token — so greedy
outputs are preemption-invariant and in-flight decodes never stall.

**Beam groups** (``Request(beam_width=W)``) are gang-scheduled: the
group claims W slots atomically (or waits), the prompt is prefilled once
into the lead slot and the other beams are ``fork_slot`` aliases — under
the paged KV layout the beams *share* their prompt-prefix blocks — and
each decode step ends with a beam reshuffle via ``reorder_slots`` (a
block-table permutation: zero KV data movement).  Preemption is atomic
too: evicting any member returns the whole group (with its per-beam
tokens and scores) to the queue.  Re-admission mirrors fresh admission:
the shared prompt is re-prefilled *once* into the lead slot, the
siblings are ``fork_slot`` aliases again (prompt sharing survives
preemption), and each beam's own emitted tokens are *replayed* through
per-slot decode steps to rebuild its divergent KV before the search
resumes.  A beam that emits ``EOS_ID`` is frozen (finished set); the
gang retires early once every beam has finished, releasing its
slots/blocks, and hypotheses are ranked by length-normalised score.
Beam groups interleave freely with ordinary requests in the same decode
batch.

**Cross-request prefix cache** (paged backends, ``FiddlerEngine(
prefix_cache=True)``, the default): at admission the backend matches the
prompt against the content-hash index over resident blocks
(models/paged_kv.PrefixIndex), splices the longest verified prefix into
the slot's block table (refcount bumps, COW on divergence) and the
engine chunk-prefills only the unmatched tail; after the join the slot's
own full prompt blocks are registered for later admissions.  Repeated
system prompts / few-shot preambles across requests are therefore
prefilled once and charged once (unique-block KV accounting).
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.faults import FaultError
from repro.data.tokenizer import EOS_ID, PAD_ID
from repro.models.paged_kv import KVPoolExhausted
from repro.serving.backend import ServingBackend, as_backend
from repro.serving.beam_search import _top_w
from repro.serving.engine import Request
from repro.serving.policy import (
    QueueView,
    SchedulerView,
    SlotView,
    StepPlan,
    get_policy,
)
from repro.serving.sampler import greedy, log_softmax

# EWMA weight for the inter-arrival-gap estimate feeding
# SchedulerView.arrival_rate (AutoscalePolicy's input).
RATE_EWMA_ALPHA = 0.3


@dataclass
class _BeamGroup:
    """Gang state of one in-flight beam group: W slots decoding in
    lockstep, reshuffled together each step.  ``done[j]`` freezes beam
    ``j`` after it emits EOS (its slot leaves the decode mask but keeps
    its KV until the gang retires); ``resuming`` marks a re-admitted
    group whose lead is re-prefilling the shared prompt."""
    req: Request
    slots: List[int]                      # member slot indices (lead first)
    scores: Optional[np.ndarray] = None   # (W,) cumulative log-probs
    tokens: List[List[int]] = field(default_factory=list)  # per-beam emitted
    done: List[bool] = field(default_factory=list)   # finished-beam set
    resuming: bool = False

    def ready(self, slots: List["_Slot"]) -> bool:
        """All members prefilled and decoding (or finished) — the gang
        barrier."""
        return all(slots[i].phase in ("decode", "done") for i in self.slots)


@dataclass
class _Slot:
    req: Optional[Request] = None
    phase: str = "idle"   # idle | prefill | reserved | replay | decode | done
    pos: int = 0               # next decode position
    last_token: int = 0
    steps_left: int = 0
    staging: Any = None        # batch-1 cache being chunk-prefilled
    prefilled: int = 0         # prompt tokens already processed
    started: Optional[float] = None  # backend-clock admission time
    group: Optional[_BeamGroup] = None  # beam-gang membership
    replay: Optional[List[int]] = None  # beam tokens re-fed after gang resume


class ContinuousEngine:
    def __init__(self, backend, params=None, *, n_slots: int = 4,
                 max_seq: int = 256, prefill_chunk: Optional[int] = None,
                 policy=None):
        """``backend``: a ``ServingBackend``, or a ``Model`` together with
        ``params`` (coerced to a ``ModelBackend`` for back-compat).
        ``prefill_chunk=None`` admits whole prompts in one step (exactly
        the monolithic prefill numerics); an integer enables chunked
        admission.  ``policy``: a ``SchedulerPolicy`` instance/name
        (default ``FIFOPolicy`` — exact pre-policy behavior)."""
        if prefill_chunk is not None and prefill_chunk < 1:
            raise ValueError(
                f"prefill_chunk must be >= 1 (or None for whole-prompt "
                f"admission), got {prefill_chunk}")
        if not isinstance(backend, ServingBackend):
            backend = as_backend(backend, params=params, max_seq=max_seq)
        assert backend.max_seq == max_seq, (backend.max_seq, max_seq)
        self.backend = backend
        self.n_slots = n_slots          # hard cap on the pool
        self.max_seq = max_seq
        self.prefill_chunk = prefill_chunk
        self.policy = get_policy(policy)
        self.queue: List[Request] = []
        self.slots = [_Slot() for _ in range(n_slots)]
        self.steps = 0
        self._ticks = 0   # scheduler ticks — the fault injector's clock
        self.finished: List[Request] = []
        # roofline constants for phase-aware policies (None = wall-clock
        # backend without a cost model)
        self._cost = backend.cost_view()
        # arrival-rate EWMA state (engine-owned so policies stay pure)
        self._rate = 0.0
        self._gap_ewma: Optional[float] = None
        self._last_arrival: Optional[float] = None
        self._rate_counted: set = set()
        # live pool: the policy sizes it; cache rows are allocated lazily
        # (grown via backend.resize_cache) so autoscaling starts small
        boot = self.policy.plan(self._view(slot_limit=1)).target_slots
        self.slot_limit = max(1, min(
            n_slots, int(n_slots if boot is None else boot)))
        self._alloc = self.slot_limit   # cache rows currently allocated
        self.cache = backend.make_cache(self._alloc)

    # ------------------------------------------------------------------
    def clock(self) -> float:
        return self.backend.clock()

    def submit(self, req: Request) -> None:
        if len(req.prompt) >= self.max_seq:
            raise ValueError(
                f"request {req.rid}: prompt length {len(req.prompt)} >= "
                f"max_seq {self.max_seq} leaves no decode budget")
        if req.beam_width > self.n_slots:
            raise ValueError(
                f"request {req.rid}: beam_width {req.beam_width} exceeds "
                f"the slot pool ({self.n_slots}) — the gang can never be "
                f"admitted")
        if req.arrival is None:
            req.arrival = self.clock()
        self.queue.append(req)

    @property
    def active(self) -> int:
        return sum(1 for s in self.slots if s.req is not None)

    # -- scheduler view -------------------------------------------------
    def _view(self, slot_limit: Optional[int] = None) -> SchedulerView:
        now = self.clock()
        q = tuple(QueueView.from_request(i, r)
                  for i, r in enumerate(self.queue))
        def _phase(sl: _Slot) -> str:
            # a gang member that finished its re-prefill while siblings
            # are still resuming is NOT decoding yet (the gang barrier
            # holds it out of the batch) — and it is not evictable either
            # (_evict refuses non-ready gangs), so don't advertise it to
            # policies as a preemption candidate
            if (sl.group is not None and sl.phase == "decode"
                    and not sl.group.ready(self.slots)):
                return "resume"
            return sl.phase

        def _remaining(sl: _Slot) -> int:
            if sl.req is None or sl.phase != "prefill":
                return 0
            return max(0, len(self._prefill_seq(sl)) - sl.prefilled)

        s = tuple(
            SlotView(index=i, rid=sl.req.rid if sl.req else None,
                     phase=_phase(sl),
                     priority=sl.req.effective_priority if sl.req else 0,
                     slo_class=sl.req.slo_class if sl.req else "standard",
                     deadline=sl.req.deadline if sl.req else None,
                     pos=sl.pos,
                     prompt_len=len(sl.req.prompt) if sl.req else 0,
                     emitted=len(sl.req.output) if sl.req else 0,
                     steps_left=sl.steps_left, started=sl.started,
                     arrival=sl.req.arrival if sl.req else None,
                     remaining_prefill=_remaining(sl),
                     gang=sl.group.req.rid if sl.group else None,
                     gang_size=len(sl.group.slots) if sl.group else 1)
            for i, sl in enumerate(self.slots))
        return SchedulerView(
            clock=now, queue=q, slots=s,
            slot_limit=self.slot_limit if slot_limit is None else slot_limit,
            max_slots=self.n_slots, arrival_rate=self._rate,
            cost=self._cost, default_chunk=self.prefill_chunk)

    def _update_rate(self, now: float) -> None:
        """EWMA the inter-arrival gap over requests whose arrival the
        clock has reached (each counted once, preemptions excluded)."""
        fresh = [r for r in self.queue
                 if r.rid not in self._rate_counted
                 and (r.arrival is None or r.arrival <= now)]
        for r in sorted(fresh, key=lambda r: (r.arrival is not None,
                                              r.arrival or 0.0)):
            self._rate_counted.add(r.rid)
            t = r.arrival if r.arrival is not None else now
            if self._last_arrival is not None:
                gap = max(t - self._last_arrival, 1e-9)
                self._gap_ewma = (gap if self._gap_ewma is None else
                                  RATE_EWMA_ALPHA * gap
                                  + (1 - RATE_EWMA_ALPHA) * self._gap_ewma)
                self._rate = 1.0 / self._gap_ewma
            self._last_arrival = t

    # -- policy mechanisms ----------------------------------------------
    # Each mechanism asks the policy for a fresh plan of the current view
    # (policies are documented pure functions of the view, so re-planning
    # after the previous mechanism's mutations is the correct reading of
    # "decide against what the engine looks like now" — e.g. admission
    # must see the queue entries that preemption just created).
    def _autoscale(self) -> None:
        target = self.policy.plan(self._view()).target_slots
        target = self.slot_limit if target is None else int(target)
        target = max(1, min(self.n_slots, target))
        # gang-admission floor: a beam group can never fit in fewer live
        # slots than its width, so an arrived gang raises the pool to its
        # width (bounded by n_slots) — otherwise a conservative policy
        # target would deadlock it in the queue
        now = self.clock()
        gangs = [r.beam_width for r in self.queue
                 if r.beam_width > 1
                 and (r.arrival is None or r.arrival <= now)]
        if gangs:
            target = max(target, min(max(gangs), self.n_slots))
        if target > self._alloc:
            self.cache = self.backend.resize_cache(self.cache,
                                                   n_slots=target)
            self._alloc = target
        self.slot_limit = target

    def _evict(self, i: int) -> None:
        """Return slot ``i``'s request to the queue carrying its emitted
        tokens; re-admission resumes it via the (chunked) prefill path.
        A beam-gang member evicts the *whole group* atomically: the
        per-beam tokens and scores are stashed on the request and every
        member slot is released."""
        slot = self.slots[i]
        if slot.req is None:
            return
        if slot.group is not None:
            grp = slot.group
            if not grp.ready(self.slots):
                return  # gangs are only preemptable once fully decoding
            req = grp.req
            req.preemptions += 1
            req.beam_resume = {"tokens": [list(t) for t in grp.tokens],
                               "scores": np.asarray(grp.scores).copy(),
                               "done": list(grp.done)}
            for si in grp.slots:
                self.cache = self.backend.release_slot(self.cache, slot=si)
                self.slots[si] = _Slot()
            self.queue.append(req)
            return
        if slot.phase != "decode":
            return  # policies may only preempt decoding slots
        req = slot.req
        req.preemptions += 1
        self.queue.append(req)
        self.cache = self.backend.release_slot(self.cache, slot=i)
        self.slots[i] = _Slot()

    def _preempt(self) -> None:
        for i in self.policy.plan(self._view()).preempt:
            if 0 <= int(i) < len(self.slots):
                self._evict(int(i))

    # ------------------------------------------------------------------
    def _admit_gang(self, req: Request, slots: List[int],
                    now: float) -> None:
        """Claim ``slots`` for a beam group atomically.  Fresh and
        resumed groups alike put only the *lead* slot into prefill (one
        shared prompt prefill — prompt sharing survives preemption) and
        reserve the siblings; on completion the lead is forked into them
        and a resumed group replays each beam's own emitted tokens to
        rebuild its divergent KV (see ``_resume_group_fork``)."""
        grp = _BeamGroup(req=req, slots=list(slots))
        resume = req.beam_resume
        grp.resuming = resume is not None
        for j, i in enumerate(slots):
            slot = self.slots[i]
            slot.req = req
            slot.group = grp
            slot.staging = None
            slot.prefilled = 0
            slot.started = now
            slot.phase = "prefill" if j == 0 else "reserved"
        if resume is not None:
            grp.tokens = [list(t) for t in resume["tokens"]]
            grp.scores = np.asarray(resume["scores"]).copy()
            grp.done = list(resume.get("done") or [False] * len(slots))
            req.beam_resume = None

    def _gang_window(self, free: List[int], width: int) -> List[int]:
        """Pick ``width`` free slots for a gang.  Backends that shard KV
        over fast devices expose ``device_of_slot``; then the gang
        prefers a single device's window (best-fit: the fullest device
        that still holds the gang), because cross-device beam forks
        cannot share prompt blocks.  Spills across devices only when no
        one device fits; without the hint this is exactly the historical
        ``free[:width]``."""
        dev_of = getattr(self.backend, "device_of_slot", None)
        if dev_of is None:
            return free[:width]
        by_dev: Dict[int, List[int]] = {}
        for i in free:
            by_dev.setdefault(dev_of(self.cache, i), []).append(i)
        fitting = [d for d in by_dev if len(by_dev[d]) >= width]
        if not fitting:
            return free[:width]
        best = min(fitting, key=lambda d: (len(by_dev[d]), d))
        return by_dev[best][:width]

    def _admit(self) -> None:
        now = self.clock()
        free = [i for i in range(self.slot_limit)
                if self.slots[i].req is None]
        if not free:
            return
        order = self.policy.plan(self._view()).admit
        chosen: set = set()  # id()s — Request is an unhashable dataclass
        for qi in order:
            if not free:
                break
            if not (0 <= int(qi) < len(self.queue)):
                continue
            req = self.queue[int(qi)]
            if id(req) in chosen or (req.arrival is not None
                                     and req.arrival > now):
                continue  # not arrived (or duplicate index): skip
            if req.beam_width > 1:
                if len(free) < req.beam_width:
                    continue  # gang admission: all W slots or none
                chosen.add(id(req))
                claimed = self._gang_window(free, req.beam_width)
                self._admit_gang(req, claimed, now)
                free = [i for i in free if i not in claimed]
                continue
            chosen.add(id(req))
            i = free.pop(0)
            slot = self.slots[i]
            slot.req = req
            slot.phase = "prefill"
            slot.staging = None
            slot.prefilled = 0
            slot.started = now
        if chosen:
            self.queue = [r for r in self.queue if id(r) not in chosen]

    def _resume_tokens(self, req: Request) -> List[int]:
        """The token sequence a preempted request must re-prefill: its
        prompt plus all emitted tokens except the last (whose KV is
        produced by the next decode step)."""
        return list(req.prompt) + list(req.output[:-1])

    def _prefill_seq(self, slot: _Slot) -> List[int]:
        """The full token sequence slot ``slot`` is prefilling: the shared
        prompt for gang leads, prompt + emitted for preempted resumes."""
        req = slot.req
        if slot.group is None and req.output:
            return self._resume_tokens(req)
        return list(req.prompt)

    def _activate_group(self, lead: int, logits: np.ndarray) -> None:
        """The lead slot's shared prompt prefill finished: pick the top-W
        distinct continuations of beam 0, fork the lead slot's KV into
        every member (block-table aliases under the paged layout — the
        beams share the prompt prefix) and release the gang into decode.
        A first token that is already EOS freezes that beam immediately."""
        slot = self.slots[lead]
        grp, req = slot.group, slot.req
        W = len(grp.slots)
        # fiddlint: ignore[FID001] beam fan-out picks tokens on host once
        # per prompt (not per step); the sync is the scheduling boundary
        logp = np.asarray(log_softmax(jnp.asarray(logits)[None]))[0]
        first = np.argsort(-logp)[:W]
        grp.scores = logp[first]
        grp.tokens = [[int(t)] for t in first]
        grp.done = [False] * W
        now = self.clock()
        req.ttft = now - req.arrival
        req.token_times.append(now)
        S = len(req.prompt)
        for j, si in enumerate(grp.slots):
            if si != lead:
                self.cache = self.backend.fork_slot(self.cache,
                                                    src=lead, dst=si)
            s = self.slots[si]
            s.phase = "decode"
            s.pos = S
            s.last_token = grp.tokens[j][0]
            s.steps_left = req.max_new_tokens - 1
            if s.last_token == EOS_ID:
                grp.done[j] = True
                s.phase = "done"
        if req.max_new_tokens <= 1 or all(grp.done):
            self._retire_group(grp)

    def _resume_group_fork(self, lead: int) -> None:
        """Gang re-admission: the shared prompt was re-prefilled *once*
        into the lead slot — fork it into every sibling (block-table
        aliases under the paged layout, so prompt sharing survives
        preemption exactly as at fresh activation) and set each live beam
        up to *replay* its own emitted tokens through per-slot decode
        steps, rebuilding the divergent KV bit-identically to the
        original decode.  The gang barrier holds the group until every
        replay finishes."""
        slot = self.slots[lead]
        grp, req = slot.group, slot.req
        grp.resuming = False
        S = len(req.prompt)
        for j, si in enumerate(grp.slots):
            if si != lead:
                self.cache = self.backend.fork_slot(self.cache,
                                                    src=lead, dst=si)
            s = self.slots[si]
            if grp.done[j]:
                s.phase = "done"  # finished before eviction: stays frozen
                continue
            beam = grp.tokens[j]
            s.pos = S
            s.last_token = beam[0]
            s.steps_left = req.max_new_tokens - len(beam)
            if len(beam) == 1:
                s.phase = "decode"   # nothing to replay
            else:
                s.phase = "replay"
                s.replay = list(beam)

    def _prefill_step(self, plan: Optional[StepPlan] = None) -> None:
        """Advance prefilling slots by one chunk (the whole remaining
        prompt when chunking is off).  ``plan.prefill`` restricts which
        slots advance this tick and ``plan.chunk_sizes`` overrides the
        engine chunk per slot (phase-aware policies); ``None`` keeps the
        legacy behavior — every prefilling slot, the engine chunk.  First
        touch probes the backend's prefix cache: the longest resident
        verified prefix is spliced into the slot's block table and only
        the unmatched tail is prefilled."""
        allowed = (None if plan is None or plan.prefill is None
                   else set(plan.prefill))
        sizes = {} if plan is None else plan.chunk_sizes
        for i, slot in enumerate(self.slots):
            if slot.phase != "prefill":
                continue
            if allowed is not None and i not in allowed:
                continue
            try:
                self._prefill_slot(i, slot, sizes)
            except (FaultError, KVPoolExhausted):
                # injected fault / pool pressure mid-prefill: recover this
                # slot through the evict→requeue→re-prefill path
                self._recover_slot(i)

    def _prefill_slot(self, i: int, slot: _Slot, sizes: Dict[int, int]
                      ) -> None:
        """One chunk of slot ``i``'s prefill (the whole loop body of
        :meth:`_prefill_step` — split out so a fault raised anywhere in
        it maps to exactly one slot's recovery)."""
        req = slot.req
        # gangs (fresh or resuming) prefill the shared prompt once,
        # into the lead slot only
        resume = slot.group is None and len(req.output) > 0
        seq = self._prefill_seq(slot)
        if slot.staging is None and slot.prefilled == 0:
            # admission: runs exactly once per prefill (a chunk is
            # processed right after, making staging/prefilled truthy)
            slot.prefilled = self.backend.match_prefix(self.cache, i, seq)
        size = sizes.get(i) or self.prefill_chunk or len(seq)
        chunk = seq[slot.prefilled: slot.prefilled + size]
        logits, slot.staging = self.backend.prefill_chunk(
            slot.staging, chunk, slot.prefilled,
            cache=self.cache, slot=i)
        slot.prefilled += len(chunk)
        if slot.prefilled < len(seq):
            return  # more chunks; in-flight decodes run meanwhile
        # prefill complete: join the multi-slot batch
        self.cache = self.backend.write_slot(self.cache, slot.staging, i)
        slot.staging = None
        self.backend.register_prefix(self.cache, i, seq)
        if slot.group is not None:
            if slot.group.resuming:
                self._resume_group_fork(i)
            else:
                self._activate_group(i, logits)
            return
        slot.phase = "decode"
        if resume:
            # decoding continues from the last emitted token; the
            # re-prefill logits (which re-predict it) are discarded
            slot.pos = len(seq)
            slot.last_token = req.output[-1]
            slot.steps_left = req.max_new_tokens - len(req.output)
            if (slot.last_token == EOS_ID or slot.steps_left <= 0
                    or slot.pos >= self.max_seq - 1):
                self._retire(i)
            return
        # fresh admission: the prompt's first generated token
        tok = int(np.argmax(logits))
        now = self.clock()
        req.output.append(tok)
        req.token_times.append(now)
        req.ttft = now - req.arrival
        slot.pos = len(req.prompt)
        slot.last_token = tok
        slot.steps_left = req.max_new_tokens - 1
        if tok == EOS_ID or slot.steps_left <= 0:
            self._retire(i)

    def _retire(self, i: int) -> None:
        slot = self.slots[i]
        if slot.req is not None:
            slot.req.latency = self.clock() - slot.req.arrival
            self.finished.append(slot.req)
        self.cache = self.backend.release_slot(self.cache, slot=i)
        self.slots[i] = _Slot()

    def _retire_group(self, grp: _BeamGroup) -> None:
        """The group finished (every beam hit EOS, or the step budget /
        sequence cap ran out): rank hypotheses by length-normalised score
        (EOS-finished beams are shorter — raw sums would unfairly favour
        them; ties keep the running descending order), report the best as
        ``output`` (all beams in ``beam_tokens``/``beam_scores``, short
        rows padded with PAD_ID) and free every member slot."""
        req = grp.req
        W = len(grp.slots)
        scores = np.asarray(grp.scores)
        lnorm = scores.astype(np.float64) / np.maximum(
            [len(t) for t in grp.tokens], 1)
        order = sorted(range(W), key=lambda j: -lnorm[j])
        toks = [list(grp.tokens[j]) for j in order]
        width = max(len(t) for t in toks)
        padded = np.full((W, width), PAD_ID, np.int32)
        for r, t in enumerate(toks):
            padded[r, : len(t)] = t
        req.output = list(toks[0])
        req.beam_tokens = padded
        req.beam_scores = scores[order]
        req.latency = self.clock() - req.arrival
        self.finished.append(req)
        for si in grp.slots:
            self.cache = self.backend.release_slot(self.cache, slot=si)
            self.slots[si] = _Slot()

    def _beam_step(self, grp: _BeamGroup, logits: np.ndarray,
                   now: float) -> None:
        """One lockstep extension of the group's *live* beams: top-k over
        their candidates, then the reshuffle — ``reorder_slots`` is a
        block-table permutation under the paged layout, so no KV moves.
        A beam whose picked token is EOS joins the finished set (slot
        frozen, KV kept); the gang retires early once all beams finish."""
        act = [j for j in range(len(grp.slots)) if not grp.done[j]]
        rows = [grp.slots[j] for j in act]
        # fiddlint: ignore[FID001] beam scoring/pruning is host-side control
        # flow over already-materialised step logits
        lp = np.asarray(log_softmax(jnp.asarray(logits[rows])))
        scores = np.array(grp.scores)  # writable copy, native dtype
        beam_idx, tok_idx, new_scores = _top_w(scores[act], lp, len(rows))
        new_tokens = [grp.tokens[act[int(b)]] + [int(t)]
                      for b, t in zip(beam_idx, tok_idx)]
        src = [rows[int(b)] for b in beam_idx]
        if src != rows:
            self.cache = self.backend.reorder_slots(self.cache,
                                                    slots=rows, src_of=src)
        budget_out = False
        for k, j in enumerate(act):
            scores[j] = new_scores[k]
            grp.tokens[j] = new_tokens[k]
            s = self.slots[rows[k]]
            s.pos += 1
            s.last_token = int(tok_idx[k])
            s.steps_left -= 1
            if s.last_token == EOS_ID:
                grp.done[j] = True
                s.phase = "done"
            budget_out = (budget_out or s.steps_left <= 0
                          or s.pos >= self.max_seq - 1)
        grp.scores = scores
        grp.req.token_times.append(now)
        if budget_out or all(grp.done):
            self._retire_group(grp)

    def _decode_step(self, plan: Optional[StepPlan] = None) -> None:
        allowed = (None if plan is None or plan.decode is None
                   else set(plan.decode))

        def live(i: int) -> bool:
            if allowed is not None and i not in allowed:
                return False
            s = self.slots[i]
            if s.phase == "replay":
                # gang resume: re-feeding a beam's own emitted tokens to
                # rebuild its KV — runs regardless of the gang barrier
                # (the replays ARE what brings the gang back)
                return True
            if s.phase != "decode":
                return False
            # gang barrier: a beam group only decodes once every member
            # is back in the batch (relevant mid-resume)
            return s.group is None or s.group.ready(self.slots)

        decoding = [live(i) for i in range(self._alloc)]
        if not any(decoding):
            return
        tokens = np.full((self._alloc,), PAD_ID, np.int32)
        pos = np.zeros((self._alloc,), np.int32)
        for i in range(self._alloc):
            if decoding[i]:
                tokens[i] = self.slots[i].last_token
                pos[i] = self.slots[i].pos
        try:
            logits, self.cache = self.backend.decode_slots(
                self.cache, tokens, pos, np.asarray(decoding))
        except (FaultError, KVPoolExhausted):
            # mid-step failure (injected pool pressure, host fault that
            # escaped the watchdog's fallback): pick a victim — lowest
            # effective priority, most KV held as the tiebreak — and
            # recover it; the surviving slots retry next tick.  Partially
            # written spans are rewritten idempotently then (fill = max,
            # COW already resolved at write time).
            cands = [i for i in range(self._alloc)
                     if decoding[i] and self.slots[i].req is not None]
            victim = min(cands, key=lambda i: (
                self.slots[i].req.effective_priority, -self.slots[i].pos))
            self._recover_slot(victim)
            return
        next_tok = greedy(logits)
        now = self.clock()
        self.steps += 1
        groups: Dict[int, _BeamGroup] = {}
        for i in range(self._alloc):
            if not decoding[i]:
                continue
            s = self.slots[i]
            if s.phase == "replay":
                # the step wrote replay[t]'s KV; its logits are known
                # history — feed the next recorded token instead
                s.pos += 1
                t = s.pos - len(s.req.prompt)  # beam tokens already written
                if t >= len(s.replay) - 1:
                    s.last_token = s.replay[-1]
                    s.replay = None
                    s.phase = "decode"  # barrier releases when all arrive
                else:
                    s.last_token = s.replay[t]
                continue
            if s.group is not None:
                groups.setdefault(id(s.group), s.group)
                continue
            tok = int(next_tok[i])
            s.req.output.append(tok)
            s.req.token_times.append(now)
            s.pos += 1
            s.last_token = tok
            s.steps_left -= 1
            if tok == EOS_ID or s.steps_left <= 0 or s.pos >= self.max_seq - 1:
                self._retire(i)
        for grp in groups.values():
            self._beam_step(grp, logits, now)

    def _requeue_slot(self, i: int) -> Optional[Request]:
        """Release slot ``i`` — the *whole gang* for a beam member, in
        any phase (unlike policy preemption, which refuses non-ready
        gangs and non-decoding slots) — stash resumable beam state, and
        return its request to the queue.  Every paged-KV block the slot
        holds is released; re-admission goes through the (chunked)
        re-prefill path.  Returns the requeued request, or ``None`` for
        an idle slot."""
        slot = self.slots[i]
        if slot.req is None:
            return None
        if slot.group is not None:
            grp = slot.group
            req = grp.req
            if grp.scores is not None:
                # live beam state (a gang still prefilling its shared
                # prompt has none — it re-admits fresh)
                req.beam_resume = {
                    "tokens": [list(t) for t in grp.tokens],
                    "scores": np.asarray(grp.scores).copy(),
                    "done": list(grp.done)}
            members = list(grp.slots)
        else:
            req = slot.req
            members = [i]
        for si in members:
            self.cache = self.backend.release_slot(self.cache, slot=si)
            self.slots[si] = _Slot()
        self.queue.append(req)
        return req

    def _recover_slot(self, i: int) -> None:
        """Fault recovery for slot ``i``: evict → requeue → (chunked)
        re-prefill, with the retry charged to the backend's ledger
        (``Ledger.retries``).  Greedy outputs are preemption-invariant,
        so recovery changes *when* tokens appear, never *which*."""
        req = self._requeue_slot(i)
        if req is not None:
            req.preemptions += 1
            self.backend.record_fault_recovery()

    def _drain_in_flight(self) -> int:
        """Step-budget exhaustion cleanup: return every in-flight
        request to the queue (outputs and beam state preserved, so a
        later ``run`` could resume them) and release all their paged-KV
        blocks — an exhausted budget must never leak pool blocks.
        Returns the number of requests drained."""
        drained = 0
        for i in range(len(self.slots)):
            if self._requeue_slot(i) is not None:
                drained += 1
        return drained

    def step(self) -> None:
        """One scheduler tick: observe arrivals → resize the live pool →
        preempt → admit → run the policy's :class:`StepPlan`.  The legacy
        (non-overlap) plan advances prefills one chunk then runs one
        decode step for every decoding slot; an overlapping plan runs the
        decode gang as the foreground stream first, then hides the
        prefill chunk's charge under it (``backend.open_overlap_window``
        — simulated clocks split the prefill stream into overlapped vs
        exposed time).  Ends with one placement-rebalance tick (dynamic
        backends may migrate experts between tiers here, charging the
        transfer to their clock — see core/rebalance.py)."""
        # fault-injection tick: arm this tick's faults (and release
        # expired KV-pressure holds) before any mechanism runs
        self.backend.begin_step(self._ticks)
        self._ticks += 1
        self._update_rate(self.clock())
        self._autoscale()
        self._preempt()
        self._admit()
        plan = self.policy.plan(self._view())
        if plan.overlap:
            t0 = self.clock()
            self._decode_step(plan)
            self.backend.open_overlap_window(max(0.0, self.clock() - t0))
            self._prefill_step(plan)
            self.backend.close_overlap_window()
        else:
            self._prefill_step(plan)
            self._decode_step(plan)
        self.backend.maybe_rebalance()

    def _admissible(self) -> bool:
        now = self.clock()
        for qi in self.policy.plan(self._view()).admit:
            if 0 <= int(qi) < len(self.queue):
                r = self.queue[int(qi)]
                if r.arrival is None or r.arrival <= now:
                    return True
        return False

    def run(self, max_steps: int = 10_000,
            on_exhausted: str = "warn", on_step=None) -> List[Request]:
        """Drive the scheduler until every request finishes or
        ``max_steps`` ticks elapse.  An exhausted step budget with work
        still queued/in flight warns (``on_exhausted="warn"``, default)
        or raises (``"raise"``) instead of silently dropping requests.
        ``on_step(engine)``, if given, is called after every tick —
        benchmarks use it to sample peak KV residency."""
        assert on_exhausted in ("warn", "raise", "ignore"), on_exhausted
        steps = 0
        while (self.queue or self.active) and steps < max_steps:
            if self.active == 0 and self.queue and not self._admissible():
                # pool idle, nothing admittable yet: fast-forward to the
                # next arrival instead of busy-spinning
                now = self.clock()
                future = [r.arrival for r in self.queue
                          if r.arrival is not None and r.arrival > now]
                if future:
                    self.backend.wait_until(min(future))
            self.step()
            if on_step is not None:
                on_step(self)
            steps += 1
        if self.queue or self.active:
            queued, in_flight = len(self.queue), self.active
            # drain in-flight slots so an exhausted budget never leaks
            # paged-KV blocks (requests keep their outputs/beam state and
            # return to the queue — a later run() could resume them)
            drained = self._drain_in_flight()
            msg = (f"ContinuousEngine.run: step budget max_steps="
                   f"{max_steps} exhausted with {queued} queued "
                   f"and {in_flight} in-flight requests unfinished "
                   f"({drained} drained back to the queue, their KV "
                   f"blocks released)")
            if on_exhausted == "raise":
                self.backend.finalize()
                raise RuntimeError(msg)
            if on_exhausted == "warn":
                warnings.warn(msg, RuntimeWarning, stacklevel=2)
        # settle in-flight migration prefetches so ledger accounting of
        # this run is complete (core/rebalance.py PrefetchQueue)
        self.backend.finalize()
        return self.finished
