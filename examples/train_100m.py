"""End-to-end training driver: a ~100M-parameter MoE model trained for a
few hundred steps on the synthetic ShareGPT pipeline, with checkpointing
and loss logging.

    PYTHONPATH=src python examples/train_100m.py [--steps 300]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig
from repro.data.pipeline import make_batch_iter
from repro.models import Model
from repro.training.checkpoint import save_checkpoint
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import train


def make_100m_config() -> ModelConfig:
    """A ~100M-param Mixtral-family model (8 experts, top-2)."""
    return ModelConfig(
        name="mixtral-100m",
        arch_type="moe",
        n_layers=8,
        d_model=512,
        n_heads=8,
        n_kv_heads=2,
        head_dim=64,
        d_ff=1024,
        vocab_size=8192,
        window=256,
        attn_pattern="sliding",
        moe=MoEConfig(n_experts=8, top_k=2),
        citation="quickstart-scale Mixtral-family model",
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()

    cfg = make_100m_config()
    model = Model(cfg, param_dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"{cfg.name}: {n_params/1e6:.1f}M parameters "
          f"({cfg.active_param_count()/1e6:.0f}M active/token analytic)")

    data = make_batch_iter(cfg, seq_len=args.seq, batch=args.batch)
    params, opt_state, hist = train(
        model, params, iter(data), n_steps=args.steps,
        opt_cfg=AdamWConfig(lr=6e-4, warmup_steps=50),
        log_every=20,
        callback=lambda s, m: print(
            f"step {s:4d}  loss={m['loss']:.4f}  lm={m['lm_loss']:.4f} "
            f"aux={m['aux_loss']:.4f}  gnorm={m['grad_norm']:.2f} "
            f"({m['wall']:.1f}s)"))
    save_checkpoint(args.ckpt, params, opt_state, step=args.steps)
    print(f"checkpoint → {args.ckpt}")
    first, last = hist[0]["loss"], hist[-1]["loss"]
    print(f"loss: {first:.3f} → {last:.3f}")
    assert last < first, "training must reduce the loss"


if __name__ == "__main__":
    main()
