"""Mixtral-8x7B [arXiv:2401.04088] — the paper's own evaluation model.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000, MoE 8e top-2,
SWA 4096. 46.7B total / 12.9B active. Used by benchmarks/e2e_latency.py etc.
"""
from repro.configs.base import ModelConfig, MoEConfig, register


@register("mixtral-8x7b")
def mixtral_8x7b() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b",
        arch_type="moe",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=32000,
        window=4096,
        attn_pattern="sliding",
        moe=MoEConfig(n_experts=8, top_k=2, router_type="softmax"),
        rope_theta=1000000.0,
        citation="[arXiv:2401.04088] Mixtral of Experts (paper's eval model)",
    )
