"""FID004 ledger-charge-completeness.

The simulated-seconds ledger is only as honest as its inputs.  Two
declarative conventions keep it so:

* every ``_charge(...)`` call site names its ``n_tokens=`` and
  ``kv_len=`` kwargs explicitly — positional workload args were the
  PR-4 bug class (swapped token/KV counts silently mis-priced a tier);
* every per-source ``*_time`` field on the ``Ledger`` dataclass comes
  with ``*_overlapped`` and ``*_exposed`` siblings, so a new time
  source cannot be added without declaring how much of it hides under
  compute versus extends the critical path (the PR-4/PR-6 migration
  accounting rule; the PR-8 per-stream serving fields —
  ``prefill_stream_*``/``decode_stream_*`` — follow the same
  convention);
* conversely, no orphan ``*_overlapped``/``*_exposed`` field may exist
  without its ``*_time`` base — a split without a total cannot be
  checked for completeness (overlapped + exposed == time).
"""
from __future__ import annotations

import ast
from typing import List

from repro.analysis.config import FiddlintConfig
from repro.analysis.core import Finding, relpath
from repro.analysis.project import Project, attr_chain


def _ledger_classes(project: Project, config: FiddlintConfig):
    for sf in project.files:
        for node in ast.walk(sf.tree):
            if (isinstance(node, ast.ClassDef)
                    and node.name == config.ledger_class):
                yield sf, node


def _field_names(cls: ast.ClassDef) -> List[ast.AnnAssign]:
    out = []
    for stmt in cls.body:
        if isinstance(stmt, ast.AnnAssign) and isinstance(
                stmt.target, ast.Name):
            out.append(stmt)
    return out


def check_ledger(project: Project,
                 config: FiddlintConfig) -> List[Finding]:
    out: List[Finding] = []

    # -- charge call sites ---------------------------------------------------
    required = list(config.charge_required_kwargs)
    for fn in project.functions.values():
        path = relpath(fn.file.path)
        for node in ast.walk(fn.node):
            if not isinstance(node, ast.Call):
                continue
            chain = attr_chain(node.func)
            if not chain or chain[-1] != config.charge_function:
                continue
            if fn.name == config.charge_function:
                continue  # the definition's own recursion/helpers
            kw = {k.arg for k in node.keywords if k.arg}
            missing = [r for r in required if r not in kw]
            if missing:
                out.append(Finding(
                    "FID004", path, node.lineno, node.col_offset,
                    f"`{config.charge_function}` call missing explicit "
                    f"{', '.join(f'`{m}=`' for m in missing)} — workload "
                    f"kwargs must be named so tiers cannot be mis-priced "
                    f"by positional swaps", fn.qualname))

    # -- Ledger time-source split --------------------------------------------
    exempt = set(config.time_split_exempt)
    for sf, cls in _ledger_classes(project, config):
        fields = _field_names(cls)
        names = {f.target.id for f in fields}  # type: ignore[union-attr]
        path = relpath(sf.path)
        for f in fields:
            name = f.target.id  # type: ignore[union-attr]
            if not name.endswith("_time") or name in exempt:
                continue
            base = name[: -len("_time")]
            missing = [s for s in (f"{base}_overlapped", f"{base}_exposed")
                       if s not in names]
            if missing:
                out.append(Finding(
                    "FID004", path, f.lineno, f.col_offset,
                    f"Ledger time source `{name}` lacks "
                    f"{', '.join(f'`{m}`' for m in missing)} — every time "
                    f"source must split into overlapped vs exposed so the "
                    f"critical-path accounting stays complete",
                    f"{sf.module}.{cls.name}"))
        for f in fields:
            name = f.target.id  # type: ignore[union-attr]
            for suffix in ("_overlapped", "_exposed"):
                if not name.endswith(suffix):
                    continue
                base = name[: -len(suffix)]
                if f"{base}_time" not in names:
                    out.append(Finding(
                        "FID004", path, f.lineno, f.col_offset,
                        f"Ledger split field `{name}` has no `{base}_time` "
                        f"base — an overlapped/exposed split without its "
                        f"total cannot be checked for completeness",
                        f"{sf.module}.{cls.name}"))
    return out
