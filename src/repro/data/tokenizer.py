"""Byte-level toy tokenizer (offline container — no external vocab files).

Maps UTF-8 bytes into the model vocabulary with a small reserved-id block,
hashing bytes upward so any ``vocab_size`` works.  Deterministic, reversible
for ids < 256 + n_reserved.
"""
from __future__ import annotations

from typing import List

PAD_ID = 0
BOS_ID = 1
EOS_ID = 2
N_RESERVED = 3


class ByteTokenizer:
    def __init__(self, vocab_size: int):
        assert vocab_size > 256 + N_RESERVED
        self.vocab_size = vocab_size

    def encode(self, text: str, bos: bool = True) -> List[int]:
        ids = [b + N_RESERVED for b in text.encode("utf-8")]
        return ([BOS_ID] if bos else []) + ids

    def decode(self, ids) -> str:
        bs = bytes(int(i) - N_RESERVED for i in ids
                   if N_RESERVED <= int(i) < 256 + N_RESERVED)
        return bs.decode("utf-8", errors="replace")
