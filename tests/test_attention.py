"""Attention: chunked (flash-style) vs dense oracle; prefill/decode cache
consistency; sliding windows; softcap; ring-buffer semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels.ref import flash_attention_ref
from repro.models import kv_cache as kvc
from repro.models.attention import (
    attention_block,
    chunked_attention,
    init_attention,
)


def _qkv(key, B, S, H, KV, hd, scale=0.3):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd)) * scale
    k = jax.random.normal(ks[1], (B, S, KV, hd)) * scale
    v = jax.random.normal(ks[2], (B, S, KV, hd)) * scale
    return q, k, v


@pytest.mark.parametrize("window", [None, 8, 64])
@pytest.mark.parametrize("chunk", [7, 16, 128])
def test_chunked_matches_dense(window, chunk):
    B, S, H, hd = 2, 65, 4, 32
    q, k, v = _qkv(jax.random.PRNGKey(0), B, S, H, H, hd)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    got = chunked_attention(q, k, v, pos, pos, causal=True, window=window,
                            kv_chunk=chunk)
    want = flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_gqa_grouping():
    """GQA: q-head h attends with kv-head h // (H/KV)."""
    B, S, H, KV, hd = 1, 16, 8, 2, 16
    q, k, v = _qkv(jax.random.PRNGKey(1), B, S, H, KV, hd)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    got = chunked_attention(q, k, v, pos, pos, causal=True, kv_chunk=8)
    k_full = jnp.repeat(k, H // KV, axis=2)
    v_full = jnp.repeat(v, H // KV, axis=2)
    want = flash_attention_ref(q, k_full, v_full, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_softcap_applied():
    B, S, H, hd = 1, 12, 2, 16
    q, k, v = _qkv(jax.random.PRNGKey(2), B, S, H, H, hd, scale=2.0)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    a = chunked_attention(q, k, v, pos, pos, attn_softcap=5.0, kv_chunk=4)
    b = flash_attention_ref(q, k, v, attn_softcap=5.0)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5,
                               atol=2e-5)
    c = chunked_attention(q, k, v, pos, pos, kv_chunk=4)
    assert float(jnp.abs(a - c).max()) > 1e-4


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "gemma2-9b", "mixtral-8x7b"])
def test_decode_matches_prefill(arch):
    """Decoding token t+1 after prefilling t tokens must equal attention
    over the full t+1 sequence."""
    cfg = get_config(arch).reduced()
    params = init_attention(jax.random.PRNGKey(0), cfg)
    B, S = 2, 12
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S + 1, cfg.d_model)) * 0.3
    pos_full = jnp.broadcast_to(jnp.arange(S + 1)[None], (B, S + 1))
    want, _ = attention_block(params, x, pos_full, cfg, 0, mode="train",
                              max_seq=S + 1)

    cache = kvc.init_attn_cache(cfg, 0, B, 32, jnp.float32)
    pos_pre = pos_full[:, :S]
    _, cache = attention_block(params, x[:, :S], pos_pre, cfg, 0,
                               mode="prefill", cache=cache, max_seq=32)
    got, _ = attention_block(params, x[:, S:], pos_full[:, S:], cfg, 0,
                             mode="decode", cache=cache, max_seq=32)
    np.testing.assert_allclose(np.asarray(got[:, 0]),
                               np.asarray(want[:, S]), rtol=2e-4, atol=2e-4)


def test_ring_buffer_wrap():
    """Windowed cache: writes past the window overwrite the oldest slot and
    decode sees exactly the last `window` positions."""
    cfg = get_config("mixtral-8x7b").reduced()  # window=64 reduced
    W = 8
    B = 1
    cache = {"k": jnp.zeros((B, W, cfg.n_kv_heads, cfg.head_dim)),
             "v": jnp.zeros((B, W, cfg.n_kv_heads, cfg.head_dim)),
             "pos": jnp.full((B, W), -1, jnp.int32)}
    for t in range(13):
        k_new = jnp.full((B, 1, cfg.n_kv_heads, cfg.head_dim), float(t))
        cache = kvc.write_decode(cache, k_new, k_new, jnp.int32(t))
    pos = np.asarray(cache["pos"][0])
    assert sorted(pos.tolist()) == list(range(5, 13))
    # slot layout: pos % W
    for slot, p in enumerate(pos):
        assert p % W == slot


def test_prefill_longer_than_window():
    """write_prefill with S > W keeps exactly the last W positions at the
    correct ring slots."""
    B, W, KV, hd, S = 1, 8, 2, 4, 20
    cache = {"k": jnp.zeros((B, W, KV, hd)), "v": jnp.zeros((B, W, KV, hd)),
             "pos": jnp.full((B, W), -1, jnp.int32)}
    k_new = jnp.arange(S, dtype=jnp.float32)[None, :, None, None] * jnp.ones((B, S, KV, hd))
    cache = kvc.write_prefill(cache, k_new, k_new)
    pos = np.asarray(cache["pos"][0])
    assert sorted(pos.tolist()) == list(range(S - W, S))
    for slot, p in enumerate(pos):
        assert p % W == slot
        assert float(cache["k"][0, slot, 0, 0]) == float(p)


def test_fully_masked_rows_are_zero_not_nan():
    B, S, H, hd = 1, 4, 2, 8
    q, k, v = _qkv(jax.random.PRNGKey(3), B, S, H, H, hd)
    pos_q = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    pos_kv = jnp.full((B, S), -1, jnp.int32)  # nothing valid
    out = chunked_attention(q, k, v, pos_q, pos_kv, causal=True, kv_chunk=2)
    assert bool(jnp.all(jnp.isfinite(out)))
    np.testing.assert_array_equal(np.asarray(out), 0.0)
